"""User-facing docs stay true: every launcher CLI flag is documented in
the README's flag table (--help-verified), and the offline markdown
checker (tools/check_docs.py, also a CI job) finds no dangling
links/anchors/§-references in README.md / DESIGN.md / CHANGES.md."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_readme_documents_every_cli_flag():
    from repro.launch.gnn_serve import build_parser as serve_parser
    from repro.launch.train import build_parser as train_parser
    readme = (ROOT / "README.md").read_text(encoding="utf-8")
    missing = []
    for build_parser in (train_parser, serve_parser):
        for action in build_parser()._actions:
            for opt in action.option_strings:
                if opt in ("-h", "--help"):
                    continue
                if f"`{opt}`" not in readme:
                    missing.append(opt)
    assert not missing, (
        f"flags missing from README.md's CLI tables: {missing} — "
        f"document them (tools/check_docs.py covers the rest of the docs)")


def test_readme_has_tier1_command():
    readme = (ROOT / "README.md").read_text(encoding="utf-8")
    roadmap = (ROOT / "ROADMAP.md").read_text(encoding="utf-8")
    # the literal command ROADMAP.md declares as the tier-1 gate
    assert "python -m pytest -x -q" in roadmap
    assert "python -m pytest -x -q" in readme, \
        "README must quote the tier-1 verify command"


def test_docs_have_no_dangling_references():
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    errors = check_docs.check_all(ROOT)
    assert not errors, "\n".join(errors)


def test_checker_catches_planted_errors(tmp_path):
    """The checker itself must not be a rubber stamp."""
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    (tmp_path / "DESIGN.md").write_text("## §1 Real\n", encoding="utf-8")
    (tmp_path / "CHANGES.md").write_text("fine\n", encoding="utf-8")
    (tmp_path / "README.md").write_text(
        "[gone](missing.md) and [bad anchor](DESIGN.md#nope)\n"
        "see DESIGN.md §9 and `not/a/file.py`\n", encoding="utf-8")
    errors = check_docs.check_all(tmp_path)
    joined = "\n".join(errors)
    assert "missing.md" in joined
    assert "#nope" in joined
    assert "§9" in joined
    assert "not/a/file.py" in joined
    # a clean corpus passes
    (tmp_path / "README.md").write_text(
        "[ok](DESIGN.md#1-real) per DESIGN.md §1\n", encoding="utf-8")
    assert check_docs.check_all(tmp_path) == []
