"""Availability suite: replicated feature plane, owner failover, hedged
reads, degraded-mode serving (ISSUE 9, DESIGN.md §12).

The headline contract: with replication r=2, a sustained single-owner
outage mid-epoch completes training with ZERO trainer restarts and final
parameters byte-identical to the no-failure run — synchronous replication
means a failover read returns exactly the primary's bytes, so faults
change accounting, never training state. The serving contract: when EVERY
copy of an owner is down, the ``InferenceServer`` keeps answering —
responses flagged ``degraded`` (stale cache / zero-fill), no unhandled
exceptions — while retry exhaustion fails only the owning handle.
"""
import jax
import numpy as np
import pytest

from repro.api import (DeadlineExceeded, DistGNNTrainer, DistGraph,
                       FaultInjector, InferenceServer, OwnerDownWindow,
                       OwnerUnavailable, RPCRetriesExhausted,
                       ServerOverloaded, TrainJobConfig)
from repro.core.kvstore import (CacheConfig, DistEmbedding, DistKVStore,
                                FeatureCache, PartitionPolicy, PeerHealth)
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig, init_gnn

FANOUTS_TYPED = {"cites": 4, "writes": 3, "rev_writes": 2, "employs": 2}
EPOCHS = 2
FOREVER = 10 ** 9


@pytest.fixture(scope="module")
def homo_ds():
    return get_dataset("product-sim", scale=10)


@pytest.fixture(scope="module")
def hetero_ds():
    return get_dataset("mag-hetero", scale=10)


def _pol(k=3, per=4):
    return PartitionPolicy("node", np.arange(k + 1) * per)


def _store(k=3, per=4, dim=3, **kw):
    s = DistKVStore({"node": _pol(k, per)}, **kw)
    full = np.arange(k * per * dim, dtype=np.float32).reshape(k * per, dim)
    s.init_data("feat", (dim,), np.float32, "node", full_array=full)
    return s, full


def _down(owner, start=0, end=FOREVER, unit="calls"):
    return FaultInjector(owner_down=[
        OwnerDownWindow(owner=owner, start=start, end=end, unit=unit)])


def _pbytes(params):
    return [np.asarray(x).tobytes()
            for x in jax.tree_util.tree_leaves(params)]


# ---------------------------------------------------------------------------
# PeerHealth circuit breaker
# ---------------------------------------------------------------------------

def test_peer_health_state_machine():
    clock = [0.0]
    h = PeerHealth(lambda: clock[0], failure_threshold=3, open_window_s=1.0)
    assert h.state(1) == PeerHealth.CLOSED and h.available(1)
    h.record_failure(1)
    h.record_failure(1)
    assert h.state(1) == PeerHealth.CLOSED, "below threshold stays closed"
    h.record_failure(1)
    assert h.state(1) == PeerHealth.OPEN and not h.available(1)
    assert h.state(2) == PeerHealth.CLOSED, "per-peer isolation"
    clock[0] = 1.5
    assert h.state(1) == PeerHealth.HALF_OPEN and h.available(1)
    h.record_failure(1)     # failed probe reopens + restarts cooldown
    assert h.state(1) == PeerHealth.OPEN
    clock[0] = 3.0
    assert h.state(1) == PeerHealth.HALF_OPEN
    h.record_success(1)     # successful probe closes fully
    assert h.state(1) == PeerHealth.CLOSED
    h.record_failure(1)
    h.record_failure(1)
    assert h.state(1) == PeerHealth.CLOSED, "success reset the streak"
    assert h.stats()["breaker_opens"] == 1


def test_success_resets_consecutive_failures():
    h = PeerHealth(lambda: 0.0, failure_threshold=2)
    for _ in range(5):
        h.record_failure(3)
        h.record_success(3)
    assert h.state(3) == PeerHealth.CLOSED


# ---------------------------------------------------------------------------
# owner-down windows (FaultInjector)
# ---------------------------------------------------------------------------

def test_calls_unit_window_is_per_owner_call_indexed():
    inj = FaultInjector(owner_down=[
        OwnerDownWindow(owner=1, start=2, end=4, unit="calls")])
    # owner 1: calls 0,1 up; 2,3 down; 4 up again. owner 0 never down.
    got = [inj.owner_is_down(1, "pull") for _ in range(5)]
    assert got == [False, False, True, True, False]
    assert not any(inj.owner_is_down(0, "pull") for _ in range(5))
    assert inj.stats()["owner_down_hits"] == 2


def test_window_is_op_scoped():
    inj = _down(0)
    assert not inj.owner_is_down(0, "data"), \
        "sampler dispatch (op='data') must not be faulted by default"
    assert inj.owner_is_down(0, "pull")


def test_batch_unit_window_follows_check_death_clock():
    inj = FaultInjector(owner_down=[
        OwnerDownWindow(owner=0, start=(1, 2), end=(1, 5), unit="batch")])
    assert not inj.owner_is_down(0, "pull"), "before the first batch"
    inj.check_death(1, 1)
    assert not inj.owner_is_down(0, "pull")
    inj.check_death(1, 2)
    assert inj.owner_is_down(0, "pull")
    inj.check_death(1, 4)
    assert inj.owner_is_down(0, "pull")
    inj.check_death(1, 5)
    assert not inj.owner_is_down(0, "pull"), "end is exclusive"
    inj.check_death(2, 0)
    assert not inj.owner_is_down(0, "pull")


def test_window_validation():
    with pytest.raises(ValueError):
        OwnerDownWindow(owner=0, start=5, end=5)
    with pytest.raises(ValueError):
        OwnerDownWindow(owner=0, start=3, end=9, unit="batch")
    with pytest.raises(ValueError):
        OwnerDownWindow(owner=0, start=0, end=9, unit="steps")


# ---------------------------------------------------------------------------
# replica placement + synchronous writes
# ---------------------------------------------------------------------------

def test_ring_placement_and_local_replica_reads():
    s, full = _store(k=3, replication=2)
    assert s.replicas_of(0) == (0, 1)
    assert s.replicas_of(2) == (2, 0)
    c = s.client(0)
    assert sorted(c._local_parts) == [0, 2]
    out = c.pull("feat", np.arange(12))
    assert np.array_equal(out, full)
    st = s.transport.stats()
    # parts 0 and 2 are shared memory (8 rows), only part 1 is remote
    assert st["remote_requests"] == 1
    assert st["local_bytes"] == 8 * 12 and st["remote_bytes"] == 4 * 12


def test_replication_clamped_to_num_parts():
    s, _ = _store(k=2, replication=5)
    assert s.replication == 2


def test_push_updates_every_copy_byte_identically():
    s, _ = _store(k=3, replication=3)
    c = s.client(0)
    ids = np.array([1, 5, 9, 5])        # one row per part + a duplicate
    vals = np.ones((4, 3), dtype=np.float32)
    c.push("feat", ids, vals, reduce="sum")
    for p in range(3):
        primary = s.servers[p].local_view("feat")
        for h in s.replicas_of(p)[1:]:
            rep = s.servers[h].replica_view("feat", p)
            assert rep.tobytes() == primary.tobytes(), (p, h)
    # the duplicate id was coalesced by np.add.at on the primary and the
    # replicas copied the result: row 5 (part 1, local 1) got +2
    assert np.allclose(s.servers[1].local_view("feat")[1],
                       np.array([17., 18., 19.]))


def test_push_grad_keeps_replica_adam_state_identical():
    s = DistKVStore({"node": _pol(3, 4)}, replication=2)
    emb = DistEmbedding(s, "emb", num=12, dim=4, policy_name="node", seed=3)
    c = s.client(0)
    rng = np.random.default_rng(0)
    for _ in range(3):
        ids = rng.integers(0, 12, size=6)
        emb.push_grad(c, ids, rng.standard_normal((6, 4)).astype(np.float32))
    for suffix in ("", "__m", "__v", "__t"):
        name = "emb" + suffix
        for p in range(3):
            primary = s.servers[p].local_view(name)
            for h in s.replicas_of(p)[1:]:
                rep = s.servers[h].replica_view(name, p)
                assert rep.tobytes() == primary.tobytes(), (name, p, h)


def test_checkpoint_restore_resyncs_replicas(tmp_path):
    from repro.checkpoint import load_kvstore, save_kvstore

    s, _ = _store(k=3, replication=2)
    s.client(0).push("feat", np.array([5]),
                     np.full((1, 3), 7, np.float32), reduce="assign")
    save_kvstore(s, str(tmp_path))

    s2, _ = _store(k=3, replication=2)
    load_kvstore(s2, str(tmp_path))
    for p in range(3):
        primary = s2.servers[p].local_view("feat")
        for h in s2.replicas_of(p)[1:]:
            assert s2.servers[h].replica_view("feat", p).tobytes() \
                == primary.tobytes()


# ---------------------------------------------------------------------------
# health-routed failover reads
# ---------------------------------------------------------------------------

def test_failover_read_is_byte_identical_and_cheap():
    s, full = _store(k=3, replication=2)
    s.transport.fault_injector = _down(1)
    c = s.client(0)     # part 1 is remote; its replica lives on server 2
    out = c.pull("feat", np.arange(12))
    assert np.array_equal(out, full), "failover read must be byte-exact"
    st = s.transport.stats()
    assert st["failovers"] == 1
    # the split retry budget caps the burn at max_rpc_retries // 2
    # attempts on the dead primary — not all 8
    assert st["owner_down_failures"] <= 4
    assert st["breaker_opens"] == 1
    # second pull: the open breaker routes to the replica FIRST — the
    # dead primary costs zero additional attempts
    before = st["owner_down_failures"]
    out2 = c.pull("feat", np.arange(12))
    assert np.array_equal(out2, full)
    st2 = s.transport.stats()
    assert st2["owner_down_failures"] == before, \
        "open breaker must not re-probe the dead primary immediately"
    assert st2["failovers"] == 2


def test_all_copies_down_raises_owner_unavailable():
    s, _ = _store(k=3, replication=2)
    s.transport.fault_injector = FaultInjector(owner_down=[
        OwnerDownWindow(owner=1, start=0, end=FOREVER),
        OwnerDownWindow(owner=2, start=0, end=FOREVER)])
    c = s.client(0)
    with pytest.raises(OwnerUnavailable):
        c.pull("feat", np.array([5]))


def test_unreplicated_transient_exhaustion_still_rpc_retries_exhausted():
    # r=1 + plain transient storms keep the PR-7 contract: the error type
    # says "flaky network", not "owner gone"
    s, _ = _store(k=3, replication=1)
    s.transport.fault_injector = FaultInjector(seed=0, rpc_failure_rate=1.0)
    with pytest.raises(RPCRetriesExhausted):
        s.client(0).pull("feat", np.array([5]))


def test_unreplicated_owner_down_raises_owner_unavailable():
    s, _ = _store(k=3, replication=1)
    s.transport.fault_injector = _down(1)
    with pytest.raises(OwnerUnavailable):
        s.client(0).pull("feat", np.array([5]))


def test_hedged_read_wins_on_down_primary():
    s, full = _store(k=3, replication=2, hedge_delay_s=0.5e-3)
    s.transport.fault_injector = _down(1)
    c = s.client(0)
    out = c.pull("feat", np.arange(12))
    assert np.array_equal(out, full)
    st = s.transport.stats()
    assert st["hedged_reads"] == 1 and st["hedge_wins"] == 1
    assert st["failovers"] == 1
    # exactly one failed primary attempt before the hedge fired — the
    # hedge path never enters the backoff rounds
    assert st["owner_down_failures"] == 1 and st["rpc_retries"] == 0


def test_hedge_never_fires_on_healthy_primary():
    s, full = _store(k=3, replication=2, hedge_delay_s=0.5e-3)
    c = s.client(0)
    assert np.array_equal(c.pull("feat", np.arange(12)), full)
    st = s.transport.stats()
    assert st["hedged_reads"] == 0 and st["hedge_wins"] == 0


def test_deferred_replica_write_keeps_copies_consistent():
    s, _ = _store(k=3, replication=2)
    # replica holder of part 1 (server 2) is down for the write; the
    # primary accepts it, the replica's copy is brought up to date via
    # the modeled write-ahead log replay, the charge is deferred
    s.transport.fault_injector = _down(2, end=20)
    c = s.client(0)
    c.push("feat", np.array([5]), np.full((1, 3), 9, np.float32),
           reduce="assign")
    st = s.transport.stats()
    assert st["deferred_replica_writes"] == 1
    assert np.allclose(s.servers[2].replica_view("feat", 1)[1], 9)
    # after the window: a failover read of part 1 serves the written bytes
    s.transport.fault_injector = _down(1)
    out = c.pull("feat", np.array([5]))
    assert np.allclose(out, 9)
    assert s.transport.stats()["failovers"] == 1


def test_write_fails_only_when_no_copy_holder_remains():
    s, _ = _store(k=2, replication=2)   # part 0 held by {0,1}, part 1 too
    s.transport.fault_injector = FaultInjector(owner_down=[
        OwnerDownWindow(owner=1, start=0, end=FOREVER)])
    c = s.client(0)
    # machine 0 is itself a holder of every part -> writes always land
    c.push("feat", np.array([1, 5]), np.ones((2, 3), np.float32))
    assert s.transport.stats()["deferred_replica_writes"] >= 1


# ---------------------------------------------------------------------------
# satellites: configurable retries + seeded backoff jitter
# ---------------------------------------------------------------------------

def test_max_rpc_retries_configurable():
    s, _ = _store(k=2, replication=1, max_rpc_retries=3)
    s.transport.fault_injector = FaultInjector(seed=0, rpc_failure_rate=1.0)
    with pytest.raises(RPCRetriesExhausted):
        s.client(0).pull("feat", np.array([5]))
    st = s.transport.stats()
    assert st["rpc_failures"] == 3 and st["rpc_retries"] == 3


def test_backoff_jitter_is_deterministic_and_desynchronized():
    def run(seed, machine):
        s, _ = _store(k=3, replication=1, jitter_seed=seed)
        s.transport.fault_injector = FaultInjector(
            seed=1, rpc_failure_rate=0.9, max_rpc_failures=6)
        c = s.client(machine)
        c.pull("feat", np.arange(12))
        return s.transport.stats()

    a, b = run(0, 0), run(0, 0)
    assert a["simulated_network_s"] == b["simulated_network_s"], \
        "same seed + same machine => identical jittered backoff schedule"
    assert a["rpc_retries"] == b["rpc_retries"]
    c = run(0, 1)
    d = run(7, 0)
    # different machine or seed desynchronizes the waits (retry counts
    # and bytes are schedule-determined, only the clock moves)
    assert c["simulated_network_s"] != a["simulated_network_s"]
    assert d["simulated_network_s"] != a["simulated_network_s"]


def test_trainjobconfig_threads_availability_knobs(homo_ds):
    job = TrainJobConfig(num_machines=3, trainers_per_machine=1,
                         replication=2, max_rpc_retries=5, hedge_ms=0.5,
                         seed=5)
    cfg = GNNConfig(arch="graphsage", in_dim=homo_ds.feats.shape[1],
                    hidden_dim=16, num_classes=homo_ds.num_classes,
                    fanouts=[3, 2], batch_size=8)
    tr = DistGNNTrainer(homo_ds, cfg, job)
    assert tr.store.replication == 2
    assert tr.store.max_rpc_retries == 5
    assert tr.store.hedge_delay_s == pytest.approx(0.5e-3)
    tr.stop()


# ---------------------------------------------------------------------------
# the headline: sustained owner outage mid-epoch, r=2, zero restarts,
# byte-identical final parameters (nc + lp, homo + typed, cache ON)
# ---------------------------------------------------------------------------

def _cfg(ds, task, typed):
    out = 16 if task == "link_prediction" else ds.num_classes
    if typed:
        return GNNConfig(arch="rgcn", in_dim=ds.feats.shape[1],
                         hidden_dim=16, num_classes=out,
                         fanouts=[dict(FANOUTS_TYPED)] * 2, batch_size=8,
                         num_rels=ds.schema.num_etypes)
    return GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                     hidden_dim=16, num_classes=out, fanouts=[3, 2],
                     batch_size=8)


def _job(task, **kw):
    # 3 machines so an r=2 outage still leaves REMOTE failover reads
    # (with k=2, r=2 every shard is local everywhere); cache ON so the
    # failover path runs under version-checked cache admission — but
    # SMALL, so evictions keep remote misses flowing during the outage
    # window (a cache big enough to hold every remote row would absorb
    # the whole epoch and the outage would never be exercised)
    return TrainJobConfig(num_machines=3, trainers_per_machine=1,
                          task=task, num_negs=4, seed=5,
                          cache=CacheConfig(budget_bytes=4096), **kw)


@pytest.mark.parametrize("task,typed", [
    ("node_classification", False),
    ("node_classification", True),
    ("link_prediction", False),
    ("link_prediction", True),
], ids=["nc-homo", "nc-typed", "lp-homo", "lp-typed"])
def test_owner_outage_trains_through_byte_identical(task, typed, homo_ds,
                                                    hetero_ds):
    ds = hetero_ds if typed else homo_ds
    cfg = _cfg(ds, task, typed)

    # no-failure reference (unreplicated: replication must be
    # byte-transparent, so r=1 clean == r=2 faulted)
    base = DistGNNTrainer(ds, cfg, _job(task))
    assert base.batches_per_epoch >= 4, "world too small for a mid-window"
    for e in range(EPOCHS):
        base.train_epoch(e)
    base_params = _pbytes(base.params)
    base.stop()

    # r=2 run with owner 2 DOWN from mid-last-epoch onward (batch clock)
    inj = FaultInjector(seed=11, owner_down=[OwnerDownWindow(
        owner=2, start=(EPOCHS - 1, 2), end=(EPOCHS, 0), unit="batch")])
    tr = DistGNNTrainer(ds, cfg, _job(task, replication=2,
                                      fault_injector=inj))
    for e in range(EPOCHS):   # NO TrainerDeath, NO recovery — zero restarts
        tr.train_epoch(e)
    assert _pbytes(tr.params) == base_params, \
        "owner outage under r=2 must not change one byte of training"
    assert inj.stats()["owner_down_hits"] > 0, "the outage never fired"
    st = tr.transport.stats()
    assert st["owner_down_failures"] > 0
    assert st["failovers"] > 0 or st["deferred_replica_writes"] > 0
    tr.stop()


# ---------------------------------------------------------------------------
# degraded-mode serving
# ---------------------------------------------------------------------------

def _world(replication=1):
    ds = get_dataset("product-sim", scale=10)
    g = DistGraph(ds, num_machines=2, trainers_per_machine=1, seed=0,
                  replication=replication)
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=8, num_classes=int(ds.num_classes),
                    fanouts=[3, 2], batch_size=4)
    return g, cfg, init_gnn(cfg, jax.random.PRNGKey(0))


def _part1_nids(g, n):
    lo, hi = int(g.book.node_offsets[1]), int(g.book.node_offsets[2])
    return np.arange(lo, lo + min(n, hi - lo), dtype=np.int64)


def test_degraded_serving_when_all_copies_down():
    g, cfg, params = _world()
    with InferenceServer(g, cfg, params,
                         cache=CacheConfig(budget_bytes=1 << 20,
                                           prewarm=False)) as srv:
        g.transport.fault_injector = _down(1)   # owner 1, r=1: no copy left
        down = srv.submit(_part1_nids(g, cfg.batch_size))
        up = srv.submit(np.arange(cfg.batch_size, dtype=np.int64))
        rows = down.result(timeout=60)       # no exception: zero-fill rows
        assert rows.shape == (cfg.batch_size, cfg.num_classes)
        assert np.isfinite(rows).all()
        assert down.degraded, "salvaged answer must be flagged"
        out = up.result(timeout=60)          # part-0 seeds still served
        assert np.isfinite(out).all()        # (frontier may cross -> flag ok)
        st = srv.stats()
        assert st["degraded_requests"] >= 1 and st["failed_requests"] == 0
        assert g.transport.stats()["degraded_pulls"] > 0


def test_warm_cache_masks_full_outage_byte_identically():
    # every remote row of the request was cached by a healthy serve and
    # feature tensors are immutable, so the outage is INVISIBLE: same
    # bytes, not even flagged — the cache is itself a replica tier
    g, cfg, params = _world()
    nids = _part1_nids(g, cfg.batch_size)
    with InferenceServer(g, cfg, params,
                         cache=CacheConfig(budget_bytes=1 << 20,
                                           prewarm=False)) as srv:
        healthy = srv.predict(nids, timeout=60)   # caches part-1 rows
        g.transport.fault_injector = _down(1)
        h = srv.submit(nids)
        assert h.result(timeout=60).tobytes() == healthy.tobytes()
        assert not h.degraded
        assert srv.stats()["failed_requests"] == 0


def test_pull_degraded_salvages_stale_cache_rows():
    s, full = _store(k=3, replication=1)
    c = s.client(0)
    cache = FeatureCache(CacheConfig(budget_bytes=1 << 20, prewarm=False))
    cache.register(s, "feat")
    c.attach_cache(cache)
    c.pull("feat", np.array([4, 5]))         # warm two part-1 rows
    s.transport.fault_injector = _down(1)
    rows, fresh = c.pull_degraded("feat", np.array([4, 5, 6, 0]))
    # the whole part-1 subset is marked stale (the miss on row 6 is what
    # surfaced the outage), the healthy owner's row stays fresh
    assert fresh.tolist() == [False, False, False, True]
    assert np.array_equal(rows[:2], full[4:6]), "stale-cache salvage"
    assert np.allclose(rows[2], 0), "uncached row zero-fills"
    assert np.array_equal(rows[3], full[0]), "healthy owner served fresh"
    assert cache.stats()["degraded_hits"] == 2
    assert s.transport.stats()["degraded_pulls"] == 3


def test_exhaustion_fails_only_its_handle():
    g, cfg, params = _world()
    with InferenceServer(g, cfg, params) as srv:
        healthy_before = srv.predict(np.arange(cfg.batch_size),
                                     timeout=60)
        # transient storm: every pull/push charge fails -> retry
        # exhaustion during THIS submit's featurization
        g.transport.fault_injector = FaultInjector(seed=0,
                                                   rpc_failure_rate=1.0)
        doomed = srv.submit(_part1_nids(g, cfg.batch_size))
        with pytest.raises(RPCRetriesExhausted):
            doomed.result(timeout=60)
        # the scheduler loop and later requests are unharmed
        g.transport.fault_injector = None
        again = srv.predict(np.arange(cfg.batch_size), timeout=60)
        assert again.tobytes() == healthy_before.tobytes()
        st = srv.stats()
        assert st["failed_requests"] == 1
        assert srv._thread.is_alive()


def test_close_fails_pending_handles():
    g, cfg, params = _world()
    # a huge coalescing window parks submitted chunks in the queue; close
    # must fail them, not leave result() hanging forever
    srv = InferenceServer(g, cfg, params, micro_batch_window_ms=60_000,
                          micro_batch_capacity=64)
    warm = srv.submit(np.arange(cfg.batch_size))     # parks in the window
    h = srv.submit(np.arange(cfg.batch_size))
    srv.close()
    for parked in (warm, h):
        with pytest.raises(RuntimeError, match="closed before"):
            parked.result(timeout=10)
    assert not srv._thread.is_alive()


def test_close_raises_if_scheduler_thread_survives():
    g, cfg, params = _world()
    srv = InferenceServer(g, cfg, params)
    real = srv._thread

    class _Stuck:
        def join(self, timeout=None):
            real.join(timeout)

        def is_alive(self):
            return True

    srv._thread = _Stuck()
    with pytest.raises(RuntimeError, match="did not stop"):
        srv.close()
    real.join(timeout=10)
    assert not real.is_alive()


def test_admission_control_sheds_overload():
    g, cfg, params = _world()
    srv = InferenceServer(g, cfg, params, micro_batch_window_ms=60_000,
                          micro_batch_capacity=64, max_pending_chunks=2)
    try:
        a = srv.submit(np.arange(cfg.batch_size))    # 1 chunk queued
        b = srv.submit(np.arange(cfg.batch_size))    # 2 chunks queued
        with pytest.raises(ServerOverloaded):
            srv.submit(np.arange(cfg.batch_size))
        assert srv.stats()["rejected_requests"] == 1
    finally:
        srv.close()   # fails the two parked chunks, exits cleanly
    for parked in (a, b):
        with pytest.raises(RuntimeError, match="closed before"):
            parked.result(timeout=10)


def test_deadline_expired_chunks_are_shed():
    g, cfg, params = _world()
    # the 1ms budget expires while the scheduler holds its 100ms
    # coalescing window open, so the chunk is shed at tick assembly —
    # never served late
    with InferenceServer(g, cfg, params, deadline_ms=1.0,
                         micro_batch_window_ms=100.0) as srv:
        h = srv.submit(np.arange(cfg.batch_size))
        with pytest.raises(DeadlineExceeded):
            h.result(timeout=60)
        assert srv.stats()["shed_chunks"] == 1
        assert srv._thread.is_alive(), "shedding must not kill the loop"
