"""PR 4 guarantees: sampler worker pools scale the pipeline front WITHOUT
changing a single byte of the training stream.

  * worker-count invariance — node and edge mini-batches (features
    included) are byte-identical for ``sample_workers`` in {1, 2, 4} and
    for the unpipelined ``sync=True`` baseline, on the homogeneous and the
    typed path, and on replay (fresh identically-seeded run);
  * the pooled stage reassembles out-of-order completions in order and
    keeps sane stats (tests in test_pipeline.py stress the raw pool);
  * typed dispatch coalesces sampling RPCs to one request per owner per
    layer (``remote_requests`` drops by the active-relation count);
  * the vectorized without-replacement subsample draws valid, unique,
    uniform positions;
  * the non-stop pipeline's consecutive-epoch contract is enforced.
"""
import hashlib

import numpy as np
import pytest

from repro.core.kvstore import (DistKVStore, NetworkModel, PartitionPolicy,
                                Transport)
from repro.core.partition import build_typed_partition, hierarchical_partition
from repro.core.pipeline import EdgeMinibatchPipeline, MinibatchPipeline
from repro.core.sampler import (DistributedSampler, EdgeBatchSampler,
                                edge_endpoints)
from repro.core.sampler.neighbor import (_subsample_positions,
                                         _subsample_positions_loop)
from repro.graph import get_dataset

WORKER_COUNTS = (1, 2, 4)
FANOUTS_TYPED = {"cites": 5, "writes": 3, "rev_writes": 2, "employs": 2}


@pytest.fixture(scope="module")
def homo_world():
    ds = get_dataset("product-sim", scale=10)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    book = hp.book
    feats_new = ds.feats[book.new2old_node]
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)})
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    return ds, hp, store


@pytest.fixture(scope="module")
def hetero_world():
    ds = get_dataset("mag-hetero", scale=10)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    book = hp.book
    typed = build_typed_partition(
        book, ds.schema, ds.graph.ntypes[book.new2old_node],
        ds.graph.etypes[book.new2old_edge])
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets),
                         **typed.policies()})
    for t, nt in enumerate(typed.schema.ntypes):
        rows = ds.feats[book.new2old_node[typed.type2node[t]]]
        store.init_data(f"feat:{nt}", rows.shape[1:], np.float32,
                        f"node:{nt}", full_array=rows)
    return ds, hp, typed, store


def _hash_node_stream(pipe, epochs=2):
    h = hashlib.sha256()
    n = 0
    for e in range(epochs):
        for mb in pipe.epoch(e):
            for b in mb.blocks:
                for arr in (b.src_gids, b.edge_src, b.edge_dst, b.edge_mask,
                            b.edge_types):
                    h.update(np.ascontiguousarray(arr).tobytes())
            h.update(mb.seeds.tobytes())
            h.update(mb.seed_mask.tobytes())
            h.update(np.int64([mb.epoch, mb.batch_index]).tobytes())
            h.update(np.ascontiguousarray(mb.input_feats).tobytes())
            n += 1
    pipe.stop()
    return h.hexdigest(), n


def _hash_edge_stream(pipe, epochs=2):
    h = hashlib.sha256()
    n = 0
    for e in range(epochs):
        for emb in pipe.epoch(e):
            for b in emb.blocks:
                for arr in (b.src_gids, b.edge_src, b.edge_dst, b.edge_mask,
                            b.edge_types):
                    h.update(np.ascontiguousarray(arr).tobytes())
            for arr in (emb.mb.seeds, emb.pos_eids, emb.pos_src, emb.pos_dst,
                        emb.neg_dst, emb.neg_v, emb.edge_etypes,
                        emb.pair_mask):
                h.update(np.ascontiguousarray(arr).tobytes())
            h.update(np.ascontiguousarray(emb.input_feats).tobytes())
            n += 1
    pipe.stop()
    return h.hexdigest(), n


# ---------------------------------------------------------------------------
# worker-count / sync / replay invariance
# ---------------------------------------------------------------------------

def test_node_batches_invariant_across_worker_counts(homo_world):
    ds, hp, store = homo_world
    book = hp.book
    seeds = book.old2new_node[ds.train_nids][:256]
    labels_new = ds.labels[book.new2old_node]

    def run(workers, sync):
        s = DistributedSampler(book, hp.partitions, [10, 5], 32, machine=0,
                               seed=5)
        pipe = MinibatchPipeline(s, store.client(0), "feat", seeds,
                                 labels=labels_new[seeds], sync=sync,
                                 non_stop=False, to_device=False, seed=6,
                                 sample_workers=workers)
        return _hash_node_stream(pipe)

    h_sync, n_sync = run(1, sync=True)
    assert n_sync == 2 * (len(seeds) // 32) > 0
    for w in WORKER_COUNTS:
        h_w, n_w = run(w, sync=False)
        assert n_w == n_sync
        assert h_w == h_sync, f"sample_workers={w} changed the node stream"
    # replay: an identically-seeded fresh run reproduces the bytes
    assert run(4, sync=False)[0] == h_sync


def test_typed_batches_invariant_across_worker_counts(hetero_world):
    ds, hp, typed, store = hetero_world
    book = hp.book
    seeds = book.old2new_node[ds.train_nids][:96]
    labels_new = ds.labels[book.new2old_node]

    def run(workers, sync):
        s = DistributedSampler(book, hp.partitions,
                               [dict(FANOUTS_TYPED)] * 2, 16, machine=0,
                               seed=15, schema=ds.schema,
                               ntype_of_node=typed.ntype_of_node)
        pipe = MinibatchPipeline(s, store.client(0), "feat", seeds,
                                 labels=labels_new[seeds], sync=sync,
                                 non_stop=False, to_device=False, seed=16,
                                 typed=typed, sample_workers=workers)
        return _hash_node_stream(pipe)

    h_sync, n_sync = run(1, sync=True)
    assert n_sync > 0
    for w in WORKER_COUNTS:
        h_w, n_w = run(w, sync=False)
        assert n_w == n_sync
        assert h_w == h_sync, f"sample_workers={w} changed the typed stream"


def test_edge_batches_invariant_across_worker_counts(homo_world):
    ds, hp, store = homo_world
    book = hp.book
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)[:512]

    def run(workers, sync):
        B, K = 32, 3
        node_bs = EdgeBatchSampler.required_node_batch(B, K)
        s = DistributedSampler(book, hp.partitions, [5, 5], node_bs,
                               machine=0, seed=25)
        es = EdgeBatchSampler(s, e_src, e_dst, owned, B, K, seed=26)
        pipe = EdgeMinibatchPipeline(es, store.client(0), "feat",
                                     sync=sync, non_stop=False,
                                     to_device=False, seed=27,
                                     sample_workers=workers)
        return _hash_edge_stream(pipe)

    h_sync, n_sync = run(1, sync=True)
    assert n_sync == 2 * (512 // 32)
    for w in WORKER_COUNTS:
        h_w, n_w = run(w, sync=False)
        assert n_w == n_sync
        assert h_w == h_sync, f"sample_workers={w} changed the edge stream"


# ---------------------------------------------------------------------------
# per-owner request coalescing (typed dispatch)
# ---------------------------------------------------------------------------

def test_typed_dispatch_coalesces_requests(hetero_world):
    ds, hp, typed, _ = hetero_world
    book = hp.book
    tp = Transport(NetworkModel())
    s = DistributedSampler(book, hp.partitions, [dict(FANOUTS_TYPED)] * 2,
                           16, machine=0, transport=tp, seed=35,
                           schema=ds.schema,
                           ntype_of_node=typed.ntype_of_node)
    seeds = book.old2new_node[ds.train_nids][:16]
    for i in range(3):
        s.sample(seeds, batch_index=i, epoch=0)
    st = s.stats
    n_active = len([k for k, v in FANOUTS_TYPED.items() if v > 0])
    assert st.owner_requests > 0
    # ONE request per remote owner per layer, carrying all active relations
    assert st.relation_requests == st.owner_requests * n_active
    assert st.request_coalescing_factor == n_active
    # the transport counts exactly the coalesced requests — this is the
    # table2 remote_requests column the benchmark reads
    assert tp.stats()["remote_requests"] == st.owner_requests


def test_untyped_dispatch_request_counting(homo_world):
    ds, hp, _ = homo_world
    book = hp.book
    tp = Transport(NetworkModel())
    s = DistributedSampler(book, hp.partitions, [10, 5], 32, machine=0,
                           transport=tp, seed=36)
    seeds = book.old2new_node[ds.train_nids][:32]
    s.sample(seeds, batch_index=0, epoch=0)
    st = s.stats
    assert st.owner_requests > 0
    assert st.relation_requests == st.owner_requests   # one relation
    assert tp.stats()["remote_requests"] == st.owner_requests


# ---------------------------------------------------------------------------
# vectorized subsample kernel
# ---------------------------------------------------------------------------

def test_vectorized_subsample_valid_unique_positions():
    rng = np.random.default_rng(3)
    degs = rng.integers(6, 40, size=200).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(degs)[:-1]])
    fanout = 5
    pos = _subsample_positions(starts, degs, fanout, rng)
    assert pos.shape == (200 * fanout,)
    for i in range(200):
        p = pos[i * fanout:(i + 1) * fanout]
        assert (p >= starts[i]).all() and (p < starts[i] + degs[i]).all()
        assert len(np.unique(p)) == fanout, "drew a position twice"


def test_vectorized_subsample_uniform():
    """Each of a seed's positions is selected with probability
    fanout/deg; 4000 trials at deg=6, fanout=2 put every empirical
    frequency within ~5 sigma of 1/3."""
    deg, fanout, trials = 6, 2, 4000
    counts = np.zeros(deg, dtype=np.int64)
    rng = np.random.default_rng(7)
    for _ in range(trials):
        p = _subsample_positions(np.array([0], dtype=np.int64),
                                 np.array([deg], dtype=np.int64), fanout, rng)
        counts[p] += 1
    freq = counts / (trials * fanout)
    sigma = np.sqrt((1 / deg) * (1 - 1 / deg) / (trials * fanout))
    assert np.abs(freq - 1 / deg).max() < 5 * sigma, freq


def test_vectorized_subsample_matches_loop_semantics():
    """Same contract as the reference loop: fanout positions per seed,
    within bounds, without replacement (streams differ — the loop is the
    benchmark baseline, not a byte oracle)."""
    rng_v = np.random.default_rng(11)
    rng_l = np.random.default_rng(11)
    degs = np.array([8, 12, 30], dtype=np.int64)
    starts = np.array([0, 100, 200], dtype=np.int64)
    pv = _subsample_positions(starts, degs, 4, rng_v)
    pl = _subsample_positions_loop(starts, degs, 4, rng_l)
    assert pv.shape == pl.shape
    for i in range(3):
        for p in (pv, pl):
            seg = p[i * 4:(i + 1) * 4]
            assert (seg >= starts[i]).all() and (seg < starts[i] + degs[i]).all()
            assert len(np.unique(seg)) == 4


# ---------------------------------------------------------------------------
# non-stop epoch contract
# ---------------------------------------------------------------------------

def test_nonstop_pipeline_rejects_non_consecutive_epochs(homo_world):
    ds, hp, store = homo_world
    book = hp.book
    seeds = book.old2new_node[ds.train_nids][:128]
    s = DistributedSampler(book, hp.partitions, [5], 32, machine=0, seed=45)
    pipe = MinibatchPipeline(s, store.client(0), "feat", seeds,
                             sync=False, non_stop=True, to_device=False,
                             seed=46)
    first = list(pipe.epoch(3))           # any starting epoch is fine
    assert len(first) == len(seeds) // 32 > 0
    assert all(mb.epoch == 3 for mb in first)
    with pytest.raises(ValueError, match="consecutive"):
        next(pipe.epoch(7))               # skipping ahead is refused
    cont = list(pipe.epoch(4))            # the consecutive epoch works
    assert all(mb.epoch == 4 for mb in cont)
    # stop() rewinds the contract: a fresh pipeline may start anywhere
    pipe.stop()
    again = list(pipe.epoch(0))
    assert all(mb.epoch == 0 for mb in again)
    pipe.stop()


# ---------------------------------------------------------------------------
# recovery fast-forward (DESIGN.md §10): epoch(e, start_batch=k) must serve
# byte-for-byte the same suffix a live run serves from position k
# ---------------------------------------------------------------------------

def _node_batch_digest(mb) -> str:
    h = hashlib.sha256()
    for b in mb.blocks:
        for arr in (b.src_gids, b.edge_src, b.edge_dst, b.edge_mask,
                    b.edge_types):
            h.update(np.ascontiguousarray(arr).tobytes())
    h.update(mb.seeds.tobytes())
    h.update(mb.seed_mask.tobytes())
    h.update(np.int64([mb.epoch, mb.batch_index]).tobytes())
    h.update(np.ascontiguousarray(mb.input_feats).tobytes())
    return h.hexdigest()


def _edge_batch_digest(emb) -> str:
    h = hashlib.sha256()
    for b in emb.blocks:
        for arr in (b.src_gids, b.edge_src, b.edge_dst, b.edge_mask,
                    b.edge_types):
            h.update(np.ascontiguousarray(arr).tobytes())
    for arr in (emb.mb.seeds, emb.pos_eids, emb.pos_src, emb.pos_dst,
                emb.neg_dst, emb.neg_v, emb.edge_etypes, emb.pair_mask):
        h.update(np.ascontiguousarray(arr).tobytes())
    h.update(np.ascontiguousarray(emb.input_feats).tobytes())
    return h.hexdigest()


def test_node_fast_forward_matches_live_suffix(homo_world):
    ds, hp, store = homo_world
    book = hp.book
    seeds = book.old2new_node[ds.train_nids][:256]
    labels_new = ds.labels[book.new2old_node]

    def pipe():
        s = DistributedSampler(book, hp.partitions, [10, 5], 32, machine=0,
                               seed=55)
        return MinibatchPipeline(s, store.client(0), "feat", seeds,
                                 labels=labels_new[seeds], non_stop=True,
                                 to_device=False, seed=56, sample_workers=2)

    live = pipe()
    full = [_node_batch_digest(mb) for mb in live.epoch(0)]
    live.stop()
    n = len(full)
    assert n >= 3
    for k in (1, n // 2, n - 1):
        ff = pipe()
        suffix = [_node_batch_digest(mb)
                  for mb in ff.epoch(0, start_batch=k)]
        ff.stop()
        assert suffix == full[k:], f"fast-forward to batch {k} diverged"


def test_edge_fast_forward_matches_live_suffix(homo_world):
    ds, hp, store = homo_world
    book = hp.book
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)[:256]

    def pipe():
        B, K = 32, 3
        s = DistributedSampler(book, hp.partitions, [5, 5],
                               EdgeBatchSampler.required_node_batch(B, K),
                               machine=0, seed=65)
        es = EdgeBatchSampler(s, e_src, e_dst, owned, B, K, seed=66)
        return EdgeMinibatchPipeline(es, store.client(0), "feat",
                                     non_stop=True, to_device=False,
                                     seed=67, sample_workers=2)

    live = pipe()
    full = [_edge_batch_digest(emb) for emb in live.epoch(0)]
    live.stop()
    n = len(full)
    assert n >= 3
    for k in (1, n // 2, n - 1):
        ff = pipe()
        suffix = [_edge_batch_digest(emb)
                  for emb in ff.epoch(0, start_batch=k)]
        ff.stop()
        assert suffix == full[k:], f"edge fast-forward to batch {k} diverged"


def test_fast_forward_spans_epoch_boundary(homo_world):
    """Only the FIRST epoch of a fast-forwarded non-stop stream is
    truncated; the next epoch replays in full from its own batch 0."""
    ds, hp, store = homo_world
    book = hp.book
    seeds = book.old2new_node[ds.train_nids][:128]

    def pipe():
        s = DistributedSampler(book, hp.partitions, [5], 32, machine=0,
                               seed=75)
        return MinibatchPipeline(s, store.client(0), "feat", seeds,
                                 non_stop=True, to_device=False, seed=76)

    live = pipe()
    e0 = [_node_batch_digest(mb) for mb in live.epoch(0)]
    e1 = [_node_batch_digest(mb) for mb in live.epoch(1)]
    live.stop()

    ff = pipe()
    assert ([_node_batch_digest(mb) for mb in ff.epoch(0, start_batch=2)]
            == e0[2:])
    assert [_node_batch_digest(mb) for mb in ff.epoch(1)] == e1
    ff.stop()


def test_typed_edge_schedule_fast_forward(hetero_world):
    """Scheduler-level check on the typed path: identical rng consumption,
    emission sliced — the surviving (etype, eids) batches match exactly."""
    ds, hp, typed, store = hetero_world
    book = hp.book
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)[:256]
    B, K = 8, 2
    s = DistributedSampler(book, hp.partitions, [dict(FANOUTS_TYPED)] * 2,
                           EdgeBatchSampler.required_node_batch(B, K),
                           machine=0, seed=85, schema=ds.schema,
                           ntype_of_node=typed.ntype_of_node)
    es = EdgeBatchSampler(s, e_src, e_dst, owned, B, K,
                          etype_of_edge=typed.etype_of_edge,
                          schema=ds.schema,
                          neg_pools=[typed.type2node[ds.schema.dst_ntype_id(r)]
                                     for r in range(ds.schema.num_etypes)],
                          seed=86)
    rng = np.random.default_rng(7)
    full = [(e, b, et, eids.tolist())
            for e, b, et, eids in es.schedule(rng, 3)]
    assert len(full) >= 3
    for k in (1, len(full) // 2, len(full) - 1):
        rng2 = np.random.default_rng(7)
        tail = [(e, b, et, eids.tolist())
                for e, b, et, eids in es.schedule(rng2, 3, start_batch=k)]
        assert tail == full[k:]


def test_fast_forward_requires_fresh_nonstop_pipeline(homo_world):
    ds, hp, store = homo_world
    book = hp.book
    seeds = book.old2new_node[ds.train_nids][:128]
    s = DistributedSampler(book, hp.partitions, [5], 32, machine=0, seed=95)
    pipe = MinibatchPipeline(s, store.client(0), "feat", seeds,
                             non_stop=True, to_device=False, seed=96)
    list(pipe.epoch(0))                   # pipeline is now live
    with pytest.raises(ValueError, match="fresh"):
        next(pipe.epoch(1, start_batch=1))
    pipe.stop()                           # rewound: fast-forward is legal
    assert len(list(pipe.epoch(1, start_batch=1))) \
        == pipe.batches_per_epoch - 1
    pipe.stop()
