"""Property-based invariant tests for the multilevel partitioner
(ISSUE 2 hardening pass). Runs under real hypothesis in CI and under the
deterministic shim in tests/_hypothesis_fallback.py offline.

Invariants:
  * assignment is total and exclusive — every vertex in exactly one part;
  * every balance constraint lands within ``(1+eps)`` of its
    per-partition average, up to a discreteness slack of two maximal
    vertex weights (one is the ``_balance_caps`` granularity envelope —
    a single vertex can weigh more than the whole eps margin — and one
    bounds the best-effort rebalance residual; empirically the residual
    stays near half that bound);
  * the reported edge cut equals a brute-force recount straight off the
    CSR, for both the multilevel and random partitioners.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import build_partitions
from repro.core.partition.multilevel import (edge_cut, make_constraints,
                                             partition_graph,
                                             random_partition)
from repro.graph import planted_partition_graph, rmat_graph
from repro.graph.generate import train_val_test_split


def _graph(kind: str, seed: int):
    if kind == "rmat-sparse":
        return rmat_graph(7, edge_factor=4, seed=seed)
    if kind == "rmat-dense":
        return rmat_graph(8, edge_factor=8, seed=seed)
    return planted_partition_graph(400, 8, seed=seed)


GRAPHS = st.sampled_from(["rmat-sparse", "rmat-dense", "planted"])


@settings(max_examples=20, deadline=None)
@given(kind=GRAPHS, k=st.integers(2, 8), seed=st.integers(0, 7))
def test_every_vertex_exactly_one_part(kind, k, seed):
    g = _graph(kind, seed)
    parts = partition_graph(g, k, seed=seed)
    assert parts.shape == (g.num_nodes,)
    assert parts.min() >= 0 and parts.max() < k
    # physical partitions: cores tile the node set exactly once
    book, gps = build_partitions(g, parts)
    assert sum(p.n_core for p in gps) == g.num_nodes
    assert int(book.node_offsets[-1]) == g.num_nodes
    assert np.array_equal(np.sort(book.new2old_node),
                          np.arange(g.num_nodes))
    per_part = np.bincount(parts, minlength=k)
    assert np.array_equal(per_part, np.diff(book.node_offsets))


@settings(max_examples=20, deadline=None)
@given(kind=GRAPHS, k=st.integers(2, 8), seed=st.integers(0, 7),
       eps=st.sampled_from([0.05, 0.08, 0.15]),
       with_split=st.booleans())
def test_balance_constraints_within_eps(kind, k, seed, eps, with_split):
    g = _graph(kind, seed)
    mask = (train_val_test_split(g.num_nodes, train_frac=0.1, seed=seed)
            if with_split else None)
    vw = make_constraints(g, mask)
    parts = partition_graph(g, k, vwgts=vw, seed=seed, eps=eps)
    loads = np.zeros((k, vw.shape[1]))
    np.add.at(loads, parts, vw)
    avg = vw.sum(axis=0) / k
    vmax = vw.max(axis=0)
    # (1+eps) of the per-partition average + discreteness slack (2 vmax):
    # indivisible vertices make the bare (1+eps)·avg bound unattainable
    bound = (1.0 + eps) * avg + 2.0 * vmax
    assert (loads <= bound + 1e-9).all(), (
        f"balance violated: loads=\n{loads}\nbound={bound}")


def _brute_force_cut(g, parts) -> float:
    """Recount crossing edges straight off the CSR, no vectorized tricks."""
    crossing = 0
    for dst in range(g.num_nodes):
        for e in range(int(g.indptr[dst]), int(g.indptr[dst + 1])):
            if parts[int(g.indices[e])] != parts[dst]:
                crossing += 1
    return crossing / max(g.num_edges, 1)


@settings(max_examples=10, deadline=None)
@given(kind=GRAPHS, k=st.integers(2, 6), seed=st.integers(0, 5),
       method=st.sampled_from(["metis", "random"]))
def test_edge_cut_matches_brute_force_recount(kind, k, seed, method):
    g = _graph(kind, seed)
    parts = (partition_graph(g, k, seed=seed) if method == "metis"
             else random_partition(g, k, seed=seed))
    assert edge_cut(g, parts) == pytest.approx(_brute_force_cut(g, parts))


def test_single_part_and_tiny_graph_degenerate_cases():
    g = rmat_graph(5, edge_factor=2, seed=0)
    assert (partition_graph(g, 1, seed=0) == 0).all()
    # n <= k: modulo assignment, still total and in range
    parts = partition_graph(g, g.num_nodes + 3, seed=0)
    assert parts.shape == (g.num_nodes,)
    assert parts.min() >= 0
